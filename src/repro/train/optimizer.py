"""Pure-JAX optimizers: AdamW and Adafactor (factored, for >=100B params).

Adafactor keeps O(rows+cols) second-moment state for matrices instead of
O(rows*cols) — the difference between kimi-k2 (1T params) fitting a 256-chip
pod or not (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, f32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(f32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(f32) * scale).astype(x.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# AdamW


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, f32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step):
        count = state["count"] + 1
        lr = lr_fn(step)
        bc1 = 1 - b1 ** count.astype(f32)
        bc2 = 1 - b2 ** count.astype(f32)

        def upd(g, m, v, p):
            g = g.astype(f32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(f32)
            return (p.astype(f32) - lr * delta).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), no-momentum variant


def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], f32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], f32)}
            return {"v": jnp.zeros(p.shape, f32)}
        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step):
        count = state["count"] + 1
        lr = lr_fn(step)
        beta = 1.0 - (count.astype(f32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(f32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_s = {"vr": vr, "vc": vc}
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": vhat}
            u = g / jnp.sqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            delta = u + weight_decay * p.astype(f32)
            return (p.astype(f32) - lr * delta).astype(p.dtype), new_s

        is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_s = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_params, {"s": new_s, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000) -> Optimizer:
    lr_fn = cosine_schedule(lr, warmup, total)
    if name == "adamw":
        return adamw(lr_fn)
    if name == "adafactor":
        return adafactor(lr_fn)
    raise ValueError(f"unknown optimizer {name!r}")
