"""Train step factory: loss + grad (accumulated over microbatches) + update.

Gradient accumulation runs as a ``lax.scan`` over microbatches with fp32
accumulators — the standard memory lever that makes the 100B+ train cells fit
(activation working set scales with microbatch, not global batch).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.optimizer import Optimizer, clip_by_global_norm

f32 = jnp.float32


def _split_microbatches(batch: Dict[str, Any], accum: int):
    def sp(x):
        B = x.shape[0]
        assert B % accum == 0, (B, accum)
        return x.reshape(accum, B // accum, *x.shape[1:])
    return {k: sp(v) for k, v in batch.items()}


def loss_and_grad(params, cfg: ModelConfig, batch):
    """Full-batch (or accumulated) loss and fp32 grads."""
    def lfn(p, mb):
        return T.train_loss(p, cfg, mb)

    if cfg.grad_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(
            params, batch)
        grads = jax.tree.map(lambda g: g.astype(f32), grads)
        return loss, metrics, grads

    mbs = _split_microbatches(batch, cfg.grad_accum)
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(lfn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(f32), g_acc, g)
        return (g_acc, loss_acc + loss), metrics

    (g_acc, loss_sum), metrics = jax.lax.scan(
        body, (zero_g, jnp.zeros((), f32)), mbs)
    n = cfg.grad_accum
    grads = jax.tree.map(lambda g: g / n, g_acc)
    metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
    return loss_sum / n, metrics, grads


def make_train_step(cfg: ModelConfig, opt: Optimizer, max_grad_norm: float = 1.0):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics). jit/pjit-able; this is what the dry-run lowers."""

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = loss_and_grad(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return params, opt_state, metrics

    return train_step
