"""Training loop: checkpoint/restart fault tolerance + straggler detection.

Restart semantics: params/opt_state/step are restored from the latest intact
checkpoint and the data pipeline is re-synced by step number (batches are a
pure function of step), so a crash at any point replays identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import (latest_step, prune_checkpoints,
                                         restore_checkpoint, save_checkpoint)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.optimizer import Optimizer, make_optimizer
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than `factor` x EWMA.

    On a real pod the flag feeds the controller that drains/replaces the slow
    host (serving does exactly that in serving/elastic.py); in-process we
    record and expose the events.
    """
    alpha: float = 0.1
    factor: float = 3.0
    ewma: Optional[float] = None
    events: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, data, *, ckpt_dir: str,
                 ckpt_every: int = 50, keep: int = 3,
                 lr: float = 3e-4, seed: int = 0,
                 donate: bool = True):
        self.cfg = cfg
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.opt = make_optimizer(cfg.optimizer, lr=lr)
        self.monitor = StragglerMonitor()
        step_fn = make_train_step(cfg, self.opt)
        self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
        self._seed = seed
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: List[Dict] = []

    # -- state ---------------------------------------------------------

    def init_or_restore(self):
        key = jax.random.PRNGKey(self._seed)
        self.params = T.init_params(self.cfg, key)
        self.opt_state = self.opt.init(self.params)
        if latest_step(self.ckpt_dir) is not None:
            tree = {"params": self.params, "opt": self.opt_state}
            tree, step, extra = restore_checkpoint(self.ckpt_dir, tree)
            self.params = tree["params"]
            self.opt_state = tree["opt"]
            self.step = step
        return self.step

    def checkpoint(self):
        save_checkpoint(self.ckpt_dir, self.step,
                        {"params": self.params, "opt": self.opt_state},
                        extra={"name": self.cfg.name})
        prune_checkpoints(self.ckpt_dir, self.keep)

    # -- loop ----------------------------------------------------------

    def train(self, num_steps: int, *,
              fail_at: Optional[int] = None,
              on_step: Optional[Callable[[int, Dict], None]] = None) -> Dict:
        """Run to global step `num_steps`. `fail_at` injects a crash (tests)."""
        if self.params is None:
            self.init_or_restore()
        while self.step < num_steps:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            batch = self.data.batch_at(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch,
                jnp.asarray(self.step, jnp.int32))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.monitor.observe(self.step, dt)
            metrics["step_s"] = dt
            self.history.append({"step": self.step, **metrics})
            if on_step:
                on_step(self.step, metrics)
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self.checkpoint()
        self.checkpoint()
        return self.history[-1] if self.history else {}
