"""The ``Workload`` protocol: timed request events + completion feedback.

The paper's headline findings are statements about *workloads* — prefill-
heavy traffic favors disaggregation (§4.2), and rate matching must track the
traffic as it shifts (§4.3) — so scenarios are first-class objects here. A
``Workload`` is pulled incrementally by ``Cluster.serve()`` through the
virtual-time event loop:

  - ``poll(now)`` returns the requests that have arrived by virtual time
    ``now`` (generated lazily — nothing is pre-materialized);
  - ``next_arrival()`` is the earliest future event time, letting an idle
    cluster jump its clock forward (or ``None`` while the workload is
    waiting on a completion — the closed-loop case);
  - ``on_complete(req, now)`` feeds finished requests back, so a multi-turn
    session can schedule turn N+1 only after turn N's ``done_t`` (think
    time included) — inexpressible with a pre-materialized request list;
  - ``summary()`` reduces the scenario to ``(isl, osl, rate,
    reuse_fraction)`` marginals, the exact inputs the analytic side
    (``core.rate_matching`` / ``core.design_space`` / ``core.frontiers``)
    consumes — the executable simulator and the analytic sweeps evaluate
    the *same* scenario objects.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.traffic import TrafficPattern
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class WorkloadSummary:
    """The ``(isl, osl, rate, reuse_fraction)`` marginals of a scenario.

    ``isl``/``osl`` are expected per-request token counts, ``rate`` the
    offered request rate (req/s; 0 for pure closed-loop workloads whose
    rate is completion-driven), ``reuse_fraction`` the expected fraction of
    prompt tokens already resident in a prefix cache (multi-turn context,
    shared system prompts) — prefill *compute* scales by
    ``1 - reuse_fraction`` while KV residency still scales with the full
    ``isl``.
    """
    isl: float
    osl: float
    rate: float = 0.0
    reuse_fraction: float = 0.0

    @property
    def effective_isl(self) -> float:
        """Prefill-compute tokens per request after KV reuse."""
        return max(1.0, self.isl * (1.0 - self.reuse_fraction))

    @property
    def prefill_heavy(self) -> bool:
        return self.effective_isl >= 4 * self.osl

    def p50_pattern(self, name: str = "workload-p50") -> TrafficPattern:
        """Closest power-of-two pattern (Appendix-C style approximation)."""
        return TrafficPattern(
            name,
            2 ** round(math.log2(max(self.isl, 1))),
            2 ** round(math.log2(max(self.osl, 1))))


@dataclasses.dataclass(frozen=True)
class SLATier:
    """A service class stamped onto emitted requests (priority + targets)."""
    name: str
    priority: int = 0
    ftl_target_s: Optional[float] = None
    ttl_target_s: Optional[float] = None

    def apply(self, req: Request) -> Request:
        req.priority = self.priority
        req.ftl_target_s = self.ftl_target_s
        req.ttl_target_s = self.ttl_target_s
        return req


# Reference tiers (round-number stand-ins for the paper's 10 s FTL cutoff
# and interactivity targets; real deployments tune these per product).
INTERACTIVE = SLATier("interactive", priority=5,
                      ftl_target_s=2.0, ttl_target_s=0.2)
STANDARD = SLATier("standard", priority=1, ftl_target_s=10.0)
BATCH = SLATier("batch", priority=0)


@runtime_checkable
class Workload(Protocol):
    """Timed request events, pulled by ``Cluster.serve()``."""

    def poll(self, now: float) -> List[Request]:
        """Requests with ``arrival_t <= now`` not yet emitted, arrival
        order. The caller owns the returned requests."""
        ...

    def next_arrival(self) -> Optional[float]:
        """Earliest known future event time, or None (exhausted, or a
        closed-loop workload waiting on ``on_complete``)."""
        ...

    def on_complete(self, req: Request, now: float) -> None:
        """Completion feedback (closed-loop hooks; no-op for open-loop)."""
        ...

    def exhausted(self) -> bool:
        """True once no further request will ever be emitted."""
        ...

    def summary(self) -> WorkloadSummary:
        ...


def materialize(workload: Workload, *, until: float = float("inf"),
                max_requests: int = 1_000_000) -> List[Request]:
    """Drain an *open-loop* workload into a flat request list (the legacy
    ``TrafficGen.generate`` surface). Closed-loop workloads cannot be
    materialized — their later events depend on completions — and raise
    rather than silently truncating to their first turns."""
    out: List[Request] = []
    while len(out) < max_requests:
        t = workload.next_arrival()
        if t is None:
            if not workload.exhausted():
                raise ValueError(
                    "closed-loop workload is waiting on completions and "
                    "cannot be materialized; drive it with Cluster.serve()")
            break
        if t > until:
            break
        out.extend(workload.poll(t))
    return out[:max_requests]


class StaticWorkload:
    """A pre-materialized request list as a ``Workload`` — what
    ``Cluster.run(requests)`` wraps. Open-loop: arrivals are fixed at
    construction and completions are ignored."""

    def __init__(self, requests: List[Request]):
        self._sorted: List[Request] = sorted(requests,
                                             key=lambda r: r.arrival_t)
        self._cursor = 0        # poll() is called once per scheduling
        #                         round; a cursor keeps it O(emitted)
        self.requests = list(requests)      # original order, for metrics

    def poll(self, now: float) -> List[Request]:
        i = self._cursor
        while i < len(self._sorted) and self._sorted[i].arrival_t <= now:
            i += 1
        out = self._sorted[self._cursor:i]
        self._cursor = i
        return out

    def next_arrival(self) -> Optional[float]:
        if self._cursor >= len(self._sorted):
            return None
        return self._sorted[self._cursor].arrival_t

    def on_complete(self, req: Request, now: float) -> None:
        pass

    def exhausted(self) -> bool:
        return self._cursor >= len(self._sorted)

    def expected_requests(self) -> float:
        return float(len(self.requests))

    def max_context(self) -> Optional[int]:
        """Largest isl+osl any request reaches (engine-capacity hint)."""
        if not self.requests:
            return None
        return max(r.isl + r.osl for r in self.requests)

    def summary(self) -> WorkloadSummary:
        rs = self.requests
        if not rs:
            return WorkloadSummary(isl=1, osl=1, rate=0.0)
        span = max(r.arrival_t for r in rs) - min(r.arrival_t for r in rs)
        return WorkloadSummary(
            isl=float(np.mean([r.isl for r in rs])),
            osl=float(np.mean([r.osl for r in rs])),
            rate=len(rs) / span if span > 0 else 0.0)


class Recorder:
    """Delegating wrapper that keeps every request a workload emits —
    for post-hoc per-request analysis (``record_trace``, mean-FTL over
    the emitted set, closed-loop assertions) without changing behavior."""

    def __init__(self, inner: Workload):
        self.inner = inner
        self.emitted: List[Request] = []

    def poll(self, now: float) -> List[Request]:
        out = self.inner.poll(now)
        self.emitted.extend(out)
        return out

    def __getattr__(self, name):
        return getattr(self.inner, name)


class Superpose:
    """Union of several workloads' event streams (e.g. an interactive tier
    superposed on a batch backfill, or two traffic phases offset in time).
    Completions are routed back to the emitting child (keyed by request
    object identity, so children sharing rid ranges still route correctly
    — though distinct ``start_rid`` ranges keep metrics legible)."""

    def __init__(self, workloads: List[Workload]):
        assert workloads
        self.children = list(workloads)
        self._owner = {}            # id(request) -> child workload

    def poll(self, now: float) -> List[Request]:
        out: List[Request] = []
        for w in self.children:
            for r in w.poll(now):
                self._owner[id(r)] = w
                out.append(r)
        out.sort(key=lambda r: r.arrival_t)
        return out

    def next_arrival(self) -> Optional[float]:
        ts = [t for t in (w.next_arrival() for w in self.children)
              if t is not None]
        return min(ts) if ts else None

    def on_complete(self, req: Request, now: float) -> None:
        w = self._owner.pop(id(req), None)
        if w is not None:
            w.on_complete(req, now)

    def exhausted(self) -> bool:
        return all(w.exhausted() for w in self.children)

    def summary(self) -> WorkloadSummary:
        """Per-request mixture of the children's marginals, weighted by
        each child's expected request count when every child can report
        one (``expected_requests``), else by offered rate — a burst of 10
        long prompts must outweigh a burst of 4 short ones."""
        ss = [w.summary() for w in self.children]
        counts = [getattr(w, "expected_requests", lambda: None)()
                  for w in self.children]
        if all(c is not None and np.isfinite(c) and c > 0 for c in counts):
            wts = [float(c) for c in counts]
        else:
            wts = [s.rate if s.rate > 0 else 1.0 for s in ss]
        tot = sum(wts)
        return WorkloadSummary(
            isl=sum(w * s.isl for w, s in zip(wts, ss)) / tot,
            osl=sum(w * s.osl for w, s in zip(wts, ss)) / tot,
            rate=sum(s.rate for s in ss),
            reuse_fraction=sum(w * s.reuse_fraction
                               for w, s in zip(wts, ss)) / tot)
