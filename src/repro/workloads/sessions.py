"""Closed-loop multi-turn sessions — the workload class the open-loop
``TrafficGen`` could never express.

A session is a conversation: turn N+1's prompt is the *entire prior
context* (system prefix + every earlier prompt and model reply) plus a
fresh user delta, and it arrives only ``think_time`` seconds after turn N
completes. That closed loop is what couples the workload to the serving
system ("Not All Prefills Are Equal", "Efficient Multi-round LLM Inference
over Disaggregated Serving"): later turns re-prefill mostly tokens whose
KV already exists somewhere, so prefix-affinity scheduling and KV-locality
routing — not just pool sizing — decide the achievable FTL.

Sessions within a *family* share a system prefix (the shared-prompt
deployment pattern), giving ``PrefixAffinityScheduler`` cross-session
locality on top of the cross-turn reuse.

Determinism: every session draws its deltas/think-times from its own
``default_rng(seed + sid)`` stream in turn order, so prompt content is a
function of (seed, model outputs) alone — independent of how the serving
side interleaves completions across sessions.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.serving.request import Request
from repro.workloads.arrivals import ArrivalProcess, Burst
from repro.workloads.base import SLATier, WorkloadSummary

Span = Union[int, Tuple[int, int]]          # fixed, or inclusive range
TimeSpan = Union[float, Tuple[float, float]]


def _draw(rng, span: Span) -> int:
    if isinstance(span, tuple):
        lo, hi = span
        return int(rng.integers(lo, hi + 1))
    return int(span)


def _draw_time(rng, span: TimeSpan) -> float:
    if isinstance(span, tuple):
        lo, hi = span
        return float(rng.uniform(lo, hi))
    return float(span)


class _Session:
    def __init__(self, sid: int, rng: np.random.Generator,
                 context: np.ndarray, turns: int):
        self.sid = sid
        self.rng = rng
        self.context = context          # prefix + all prior prompts/replies
        self.turns_left = turns
        self.turn = 0


class SessionWorkload:
    """Multi-turn conversations with think time (closed loop)."""

    def __init__(self, *, vocab: int, seed: int = 0, sessions: int = 4,
                 arrivals: Optional[ArrivalProcess] = None,
                 turns: Span = 3, families: int = 1,
                 system_prefix_len: int = 32, user_isl: Span = 16,
                 osl: Span = 8, think_time: TimeSpan = 0.0,
                 tier: Optional[SLATier] = None, start_rid: int = 0):
        assert vocab > 0 and sessions > 0 and families > 0
        self.vocab = vocab
        self.n_sessions = sessions
        self.families = families
        self.system_prefix_len = system_prefix_len
        self.user_isl = user_isl
        self.osl_span = osl
        self.turns_span = turns
        self.think_time = think_time
        self.tier = tier
        self._ids = itertools.count(start_rid)
        self._seq = itertools.count()       # heap tiebreak

        root = np.random.default_rng(seed)
        prefixes = [root.integers(0, vocab, size=system_prefix_len
                                  ).astype(np.int32)
                    for _ in range(families)]
        starts = self._session_starts(arrivals, root)
        # (time, seq, request) events not yet emitted; later turns are
        # pushed by on_complete
        self._pending: List[Tuple[float, int, Request]] = []
        self._owner: Dict[int, _Session] = {}       # rid -> session
        self._active = 0                            # sessions not finished
        for sid, t0 in enumerate(starts):
            s = _Session(sid, np.random.default_rng(seed + 1 + sid),
                         prefixes[sid % families].copy(),
                         _draw(root, turns))
            self._active += 1
            self._schedule_turn(s, t0)

    def _session_starts(self, arrivals: Optional[ArrivalProcess], rng
                        ) -> List[float]:
        proc = arrivals or Burst(self.n_sessions, at=0.0)
        out, t = [], 0.0
        for _ in range(self.n_sessions):
            nxt = proc.next_after(rng, t)
            if nxt is None:
                break
            out.append(nxt)
            t = nxt
        return out

    def _schedule_turn(self, s: _Session, at: float) -> None:
        delta = s.rng.integers(0, self.vocab,
                               size=_draw(s.rng, self.user_isl)
                               ).astype(np.int32)
        prompt = np.concatenate([s.context, delta])
        req = Request(rid=next(self._ids), prompt=prompt,
                      osl=_draw(s.rng, self.osl_span), arrival_t=at,
                      session_id=s.sid, turn=s.turn)
        if self.tier is not None:
            self.tier.apply(req)
        s.turn += 1
        s.turns_left -= 1
        self._owner[req.rid] = s
        heapq.heappush(self._pending, (at, next(self._seq), req))

    # -- Workload protocol -------------------------------------------------

    def poll(self, now: float) -> List[Request]:
        out: List[Request] = []
        while self._pending and self._pending[0][0] <= now:
            out.append(heapq.heappop(self._pending)[2])
        return out

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    def on_complete(self, req: Request, now: float) -> None:
        s = self._owner.pop(req.rid, None)
        if s is None:
            return
        # the conversation so far = this turn's prompt + the model's reply
        reply = np.asarray(req.output, dtype=np.int32) % self.vocab
        s.context = np.concatenate([req.prompt, reply])
        if s.turns_left > 0:
            self._schedule_turn(s, now + _draw_time(s.rng, self.think_time))
        else:
            self._active -= 1

    def exhausted(self) -> bool:
        return self._active == 0 and not self._pending

    def expected_requests(self) -> float:
        n = (sum(self.turns_span) / 2 if isinstance(self.turns_span, tuple)
             else float(self.turns_span))
        return self.n_sessions * max(n, 1.0)

    def max_context(self) -> int:
        """Largest isl+osl the final turn can reach (capacity hint)."""
        hi = (lambda s: s[1] if isinstance(s, tuple) else s)
        n = int(hi(self.turns_span))
        u, o = int(hi(self.user_isl)), int(hi(self.osl_span))
        return self.system_prefix_len + n * (u + o)

    def summary(self) -> WorkloadSummary:
        """Expected marginals over a session's turns. Turn k's prompt is
        ``P + k*u + (k-1)*o`` tokens of which all but the fresh ``u`` user
        tokens already sat in some prefix cache (prior context; the family
        prefix for turn 1)."""
        P = float(self.system_prefix_len)
        u = (sum(self.user_isl) / 2 if isinstance(self.user_isl, tuple)
             else float(self.user_isl))
        o = (sum(self.osl_span) / 2 if isinstance(self.osl_span, tuple)
             else float(self.osl_span))
        n = (sum(self.turns_span) / 2 if isinstance(self.turns_span, tuple)
             else float(self.turns_span))
        n = max(n, 1.0)
        lens = [P + k * u + (k - 1) * o for k in range(1, int(round(n)) + 1)]
        shared = [L - u for L in lens]
        return WorkloadSummary(
            isl=float(np.mean(lens)), osl=o, rate=0.0,
            reuse_fraction=float(sum(shared) / max(sum(lens), 1.0)))
