"""JSONL trace replay (and recording) — production-trace workloads.

Record format, one JSON object per line (à la the sglang /
production-stack benchmark traces):

    {"arrival_t": 0.12, "isl": 512, "osl": 64}
    {"arrival_t": 0.30, "isl": 48, "osl": 8, "priority": 5,
     "ftl_target_s": 0.5, "session_id": 3, "prompt": [17, 4, ...]}

``arrival_t`` (alias ``ts``) is seconds from trace start; ``prompt`` is
optional — absent prompts are synthesized deterministically from the seed
(token *content* rarely survives into traces; shape and timing do).
``record_trace`` writes served requests back out in the same format, so a
live run can be re-served as a replay.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Union

import numpy as np

from repro.serving.request import Request
from repro.workloads.base import StaticWorkload

Record = Dict[str, object]


def _load_records(source: Union[str, os.PathLike, Iterable[Record]]
                  ) -> List[Record]:
    if isinstance(source, (str, os.PathLike)):
        with open(source) as f:
            return [json.loads(line) for line in f if line.strip()]
    return [dict(r) for r in source]


class TraceReplay(StaticWorkload):
    """Replay a JSONL trace (path or iterable of records) as a workload.

    Open-loop by construction: the trace's timestamps are honored as-is
    (scaled by ``time_scale``; < 1 compresses, > 1 stretches), which is
    exactly what makes a replay comparable across policy stacks.
    """

    def __init__(self, source: Union[str, os.PathLike, Iterable[Record]],
                 *, vocab: int, seed: int = 0, time_scale: float = 1.0,
                 start_rid: int = 0):
        assert vocab > 0 and time_scale > 0
        rng = np.random.default_rng(seed)
        requests: List[Request] = []
        for i, rec in enumerate(_load_records(source)):
            t = float(rec.get("arrival_t", rec.get("ts", 0.0))) * time_scale
            if "prompt" in rec:
                prompt = np.asarray(rec["prompt"], dtype=np.int32) % vocab
            else:
                prompt = rng.integers(0, vocab, size=int(rec["isl"])
                                      ).astype(np.int32)
            requests.append(Request(
                rid=start_rid + i, prompt=prompt, osl=int(rec["osl"]),
                arrival_t=t,
                priority=int(rec.get("priority", 0)),
                ftl_target_s=rec.get("ftl_target_s"),
                ttl_target_s=rec.get("ttl_target_s"),
                session_id=rec.get("session_id"),
                turn=int(rec.get("turn", 0))))
        super().__init__(requests)


def record_trace(requests: Iterable[Request],
                 path: Union[str, os.PathLike, None] = None, *,
                 with_prompts: bool = False) -> List[Record]:
    """Serialize served (or generated) requests as trace records; writes
    JSONL to ``path`` when given. Round-trips through ``TraceReplay``."""
    records: List[Record] = []
    for r in sorted(requests, key=lambda r: (r.arrival_t, r.rid)):
        rec: Record = {"arrival_t": r.arrival_t, "isl": r.isl, "osl": r.osl}
        if r.priority:
            rec["priority"] = r.priority
        if r.ftl_target_s is not None:
            rec["ftl_target_s"] = r.ftl_target_s
        if r.ttl_target_s is not None:
            rec["ttl_target_s"] = r.ttl_target_s
        if r.session_id is not None:
            rec["session_id"] = r.session_id
            rec["turn"] = r.turn
        if with_prompts:
            rec["prompt"] = [int(t) for t in r.prompt]
        records.append(rec)
    if path is not None:
        with open(path, "w") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    return records
