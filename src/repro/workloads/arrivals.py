"""Arrival processes: *when* requests show up.

An ``ArrivalProcess`` yields inter-arrival gaps against the cluster's
virtual clock. Composed with a ``ShapeSampler`` by ``OpenLoopWorkload``
(workloads/generators.py); the diurnal / piecewise processes subsume the
old hand-built two-phase ``TrafficGen`` hacks (``rate=1e6`` bursts, manual
``arrival_t`` offsets) the examples used to fake non-Poisson traffic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class ArrivalProcess(Protocol):
    def next_after(self, rng: np.random.Generator, t: float
                   ) -> Optional[float]:
        """Absolute time of the next arrival strictly after ``t`` (monotone
        non-decreasing across calls), or None when the process is spent."""
        ...

    def mean_rate(self) -> float:
        """Long-run offered request rate (req/s) for summaries."""
        ...


@dataclasses.dataclass
class Poisson:
    """Memoryless arrivals at a constant rate (the classic open-loop M/·)."""
    rate: float

    def __post_init__(self):
        assert self.rate > 0

    def next_after(self, rng, t):
        return t + rng.exponential(1.0 / self.rate)

    def mean_rate(self):
        return self.rate


@dataclasses.dataclass
class Burst:
    """``size`` arrivals at time ``at`` (optionally ``spacing`` seconds
    apart) — replaces the ``rate=1e6`` Poisson hack for closed bursts."""
    size: int
    at: float = 0.0
    spacing: float = 0.0

    def __post_init__(self):
        assert self.size > 0
        self._emitted = 0

    def next_after(self, rng, t):
        if self._emitted >= self.size:
            return None
        t_i = self.at + self._emitted * self.spacing
        self._emitted += 1
        return max(t, t_i)

    def mean_rate(self):
        if self.spacing > 0:
            return 1.0 / self.spacing
        return float("inf")


@dataclasses.dataclass
class PiecewiseRate:
    """Piecewise-constant Poisson: ``phases = [(duration_s, rate), ...]``.

    Exact (not an approximation): exponential gaps are memoryless, so a draw
    that crosses a phase boundary is simply re-drawn from the boundary at
    the new rate. ``repeat=True`` tiles the schedule forever (a square-wave
    diurnal cycle); otherwise the process ends after the last phase.
    """
    phases: Sequence[Tuple[float, float]]
    repeat: bool = False

    def __post_init__(self):
        assert self.phases and all(d > 0 and r >= 0 for d, r in self.phases)
        self._period = sum(d for d, _ in self.phases)

    def _phase_at(self, t: float) -> Tuple[float, float]:
        """(rate, end_time) of the phase containing absolute time t."""
        if self.repeat:
            base = math.floor(t / self._period) * self._period
        else:
            base = 0.0
        local = t - base
        acc = 0.0
        for dur, rate in self.phases:
            acc += dur
            if local < acc:
                return rate, base + acc
        return 0.0, float("inf")        # past the schedule (repeat=False)

    def next_after(self, rng, t):
        t = max(t, 0.0)
        while True:
            rate, end = self._phase_at(t)
            if not self.repeat and t >= self._period:
                return None
            if rate <= 0:               # silent phase: jump to its end
                t = end
                continue
            gap = rng.exponential(1.0 / rate)
            if t + gap <= end:
                return t + gap
            t = end                     # crossed the boundary: restart there

    def mean_rate(self):
        return sum(d * r for d, r in self.phases) / self._period


@dataclasses.dataclass
class Diurnal:
    """Sinusoidal-rate Poisson via thinning (exact):
    ``rate(t) = base * (1 + amplitude * sin(2*pi*t/period + phase))``."""
    base: float
    amplitude: float = 0.5
    period: float = 86400.0
    phase: float = 0.0

    def __post_init__(self):
        assert self.base > 0 and 0 <= self.amplitude <= 1

    def _rate(self, t: float) -> float:
        return self.base * (1 + self.amplitude *
                            math.sin(2 * math.pi * t / self.period
                                     + self.phase))

    def next_after(self, rng, t):
        peak = self.base * (1 + self.amplitude)
        while True:
            t = t + rng.exponential(1.0 / peak)
            if rng.uniform() * peak <= self._rate(t):
                return t

    def mean_rate(self):
        return self.base


class Merged:
    """Superposition of arrival processes (rates add)."""

    def __init__(self, processes: List[ArrivalProcess]):
        assert processes
        self.processes = list(processes)
        self._pending: List[Optional[float]] = [None] * len(processes)

    def next_after(self, rng, t):
        for i, p in enumerate(self.processes):
            if self._pending[i] is None:
                self._pending[i] = p.next_after(rng, t)
        live = [x for x in self._pending if x is not None]
        if not live:
            return None
        nxt = min(live)
        self._pending[self._pending.index(nxt)] = None
        return nxt

    def mean_rate(self):
        return sum(p.mean_rate() for p in self.processes)
