"""Workload API: closed-loop scenario generation for ``Cluster.serve()``
and the analytic sweeps (see docs/workloads.md).

A scenario composes *when* (``arrivals``), *how big* (``shapes``), and
*how it reacts* (open-loop generators vs closed-loop sessions), and
summarizes itself to the ``(isl, osl, rate, reuse_fraction)`` marginals
the analytic side consumes — one scenario object, both evaluators.
"""
from repro.workloads.arrivals import (ArrivalProcess, Burst, Diurnal, Merged,
                                      PiecewiseRate, Poisson)
from repro.workloads.base import (BATCH, INTERACTIVE, STANDARD, Recorder,
                                  SLATier, StaticWorkload, Superpose,
                                  Workload, WorkloadSummary, materialize)
from repro.workloads.generators import OpenLoopWorkload
from repro.workloads.sessions import SessionWorkload
from repro.workloads.shapes import (PATTERN_SHAPES, FixedShape,
                                    LognormalShape, MixtureShape,
                                    ShapeSampler)
from repro.workloads.trace import TraceReplay, record_trace

__all__ = [
    "Workload", "WorkloadSummary", "StaticWorkload", "Superpose",
    "Recorder", "materialize",
    "SLATier", "INTERACTIVE", "STANDARD", "BATCH",
    "ArrivalProcess", "Poisson", "Burst", "PiecewiseRate", "Diurnal",
    "Merged",
    "ShapeSampler", "FixedShape", "LognormalShape", "MixtureShape",
    "PATTERN_SHAPES",
    "OpenLoopWorkload", "SessionWorkload",
    "TraceReplay", "record_trace",
]
