"""Shape samplers: *how big* each request is (ISL/OSL).

These wrap the analytic traffic models in ``core.traffic`` — the four §4.2
patterns and the Appendix-C lognormal — behind one sampling protocol, plus
mixtures of either. ``expected()`` exposes the marginals the analytic
sweeps consume via ``WorkloadSummary``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.traffic import PATTERNS, DynamicTraffic, TrafficPattern


@runtime_checkable
class ShapeSampler(Protocol):
    def sample(self, rng: np.random.Generator) -> Tuple[int, int]:
        """One (isl, osl) draw."""
        ...

    def expected(self) -> Tuple[float, float]:
        """(E[isl], E[osl]) — the summary marginals."""
        ...


@dataclasses.dataclass(frozen=True)
class FixedShape:
    """Constant ISL/OSL (the paper's power-of-two P50 approximations)."""
    isl: int
    osl: int

    @classmethod
    def from_pattern(cls, pattern: TrafficPattern) -> "FixedShape":
        return cls(pattern.isl, pattern.osl)

    def sample(self, rng):
        return self.isl, self.osl

    def expected(self):
        return float(self.isl), float(self.osl)


@dataclasses.dataclass(frozen=True)
class LognormalShape:
    """Appendix-C lognormal ISL/OSL mixture (``core.traffic.DynamicTraffic``
    as a per-request sampler)."""
    median_isl: int
    median_osl: int
    sigma_isl: float = 0.8
    sigma_osl: float = 0.7

    @classmethod
    def from_dynamic(cls, dyn: DynamicTraffic) -> "LognormalShape":
        return cls(dyn.median_isl, dyn.median_osl,
                   dyn.sigma_isl, dyn.sigma_osl)

    def sample(self, rng):
        isl = math.exp(rng.normal(math.log(self.median_isl), self.sigma_isl))
        osl = math.exp(rng.normal(math.log(self.median_osl), self.sigma_osl))
        return max(1, int(isl)), max(1, int(osl))

    def expected(self):
        # lognormal mean = median * exp(sigma^2 / 2)
        return (self.median_isl * math.exp(self.sigma_isl ** 2 / 2),
                self.median_osl * math.exp(self.sigma_osl ** 2 / 2))


class MixtureShape:
    """Weighted mixture of shape samplers (e.g. 80% chat + 20% long-doc)."""

    def __init__(self, components: Sequence[Tuple[float, ShapeSampler]]):
        assert components
        self.samplers = [s for _, s in components]
        w = np.asarray([max(float(x), 0.0) for x, _ in components])
        assert w.sum() > 0
        self.weights = w / w.sum()

    def sample(self, rng):
        i = int(rng.choice(len(self.samplers), p=self.weights))
        return self.samplers[i].sample(rng)

    def expected(self):
        ei = sum(w * s.expected()[0]
                 for w, s in zip(self.weights, self.samplers))
        eo = sum(w * s.expected()[1]
                 for w, s in zip(self.weights, self.samplers))
        return float(ei), float(eo)


# The four §4.2 patterns as ready-made samplers, keyed by pattern name.
PATTERN_SHAPES = {p.name: FixedShape.from_pattern(p) for p in PATTERNS}
