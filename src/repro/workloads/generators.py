"""Open-loop workload generation: ``ArrivalProcess`` x ``ShapeSampler``.

``OpenLoopWorkload`` is the lazy, pull-based replacement for the legacy
``TrafficGen.generate()`` pre-materialized list: requests exist only once
the cluster's virtual clock reaches them, so unbounded processes (diurnal
cycles, long traces) serve in O(1) memory, and the same object yields the
``(isl, osl, rate, reuse)`` marginals for the analytic sweeps.
"""
from __future__ import annotations

import itertools
from typing import Callable, List, Optional, Union

import numpy as np

from repro.serving.request import Request
from repro.workloads.arrivals import ArrivalProcess
from repro.workloads.base import SLATier, WorkloadSummary
from repro.workloads.shapes import ShapeSampler

TierLike = Union[SLATier, Callable[[np.random.Generator], SLATier], None]


def _stamp_tier(req: Request, tier: TierLike, rng) -> Request:
    if tier is None:
        return req
    if isinstance(tier, SLATier):
        return tier.apply(req)
    return tier(rng).apply(req)


class OpenLoopWorkload:
    """Timed single-turn requests from an arrival process and a shape
    sampler. Open loop: the stream never reacts to completions, so the
    same seed always yields the identical event stream."""

    def __init__(self, arrivals: ArrivalProcess, shape: ShapeSampler, *,
                 vocab: int, seed: int = 0, max_requests: int = 10_000,
                 horizon_s: float = float("inf"), tier: TierLike = None,
                 start_rid: int = 0):
        assert vocab > 0
        self.arrivals = arrivals
        self.shape = shape
        self.vocab = vocab
        self.max_requests = max_requests
        self.horizon_s = horizon_s
        self.tier = tier
        self.rng = np.random.default_rng(seed)
        self._ids = itertools.count(start_rid)
        self._t = 0.0
        self._emitted = 0
        self._spent = False
        self._next: Optional[Request] = None
        self._advance()

    def _advance(self) -> None:
        """Lazily draw the next request (one event of lookahead, so
        ``next_arrival`` is always known)."""
        self._next = None
        if self._spent or self._emitted >= self.max_requests:
            self._spent = True
            return
        t = self.arrivals.next_after(self.rng, self._t)
        if t is None or t > self.horizon_s:
            self._spent = True
            return
        isl, osl = self.shape.sample(self.rng)
        prompt = self.rng.integers(0, self.vocab, size=isl).astype(np.int32)
        req = Request(rid=next(self._ids), prompt=prompt, osl=osl,
                      arrival_t=t)
        self._next = _stamp_tier(req, self.tier, self.rng)
        self._t = t
        self._emitted += 1

    # -- Workload protocol -------------------------------------------------

    def poll(self, now: float) -> List[Request]:
        out: List[Request] = []
        while self._next is not None and self._next.arrival_t <= now:
            out.append(self._next)
            self._advance()
        return out

    def next_arrival(self) -> Optional[float]:
        return self._next.arrival_t if self._next is not None else None

    def on_complete(self, req: Request, now: float) -> None:
        pass

    def exhausted(self) -> bool:
        return self._next is None

    def expected_requests(self) -> float:
        """Expected emission count — the mixture weight ``Superpose`` uses.
        A count-limited arrival process (``Burst.size``) wins over the
        rate x horizon estimate; an unbounded process falls back to the
        ``max_requests`` cap (which is what will actually be emitted)."""
        n = float(self.max_requests)
        size = getattr(self.arrivals, "size", None)
        if size is not None:
            n = min(n, float(size))
        rate = self.arrivals.mean_rate()
        if np.isfinite(rate) and np.isfinite(self.horizon_s):
            n = min(n, rate * self.horizon_s)
        return n

    def summary(self) -> WorkloadSummary:
        isl, osl = self.shape.expected()
        rate = self.arrivals.mean_rate()
        return WorkloadSummary(isl=isl, osl=osl,
                               rate=rate if np.isfinite(rate) else 0.0)
