"""Closed-loop multi-turn sessions: the first workload that actually
rewards KV locality.

A ``SessionWorkload`` emits conversations — turn N+1's prompt is the whole
prior context plus a fresh user delta, and it *arrives only after turn N
completes* (think time included). The same fleet serves it under two
policy stacks:

  affinity = PrefixAffinityScheduler + KVLocalityRouter  (keep each
             conversation on the engine already holding its KV)
  naive    = FCFSScheduler + RoundRobinRouter            (placement blind)

Affinity wins on prefix-cache hit tokens (it re-prefills only the new
delta) and, once jit caches are warm, on mean first-token latency (each
stack first serves a warm-up episode with the same *shapes* but a
different seed, so compiles never pollute the measured pass and prompt
content never collides with it). The very same workload object then feeds
the *analytic* sweep: ``workload_frontier`` consumes its
``(isl, osl, reuse_fraction)`` marginals, so the paper-style frontier and
the executable run describe one scenario.

  PYTHONPATH=src python examples/multi_turn_sessions.py
"""
import jax
import numpy as np

from repro.core.frontiers import workload_frontier
from repro.core.paper_models import LLAMA31_70B
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.cluster import Cluster
from repro.serving.engine import Engine
from repro.serving.policies import (FCFSScheduler, KVLocalityRouter,
                                    PrefixAffinityScheduler, RoundRobinRouter)
from repro.workloads import Recorder, SessionWorkload

cfg = ModelConfig(name="chat-small", family="dense", num_layers=4,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=97, remat=False, logits_chunk=32,
                  dtype="float32")
params = T.init_params(cfg, jax.random.PRNGKey(0))
CHUNK, CAP = 16, 448


def sessions(seed):
    # 6 conversations, 3 turns each, two shared system prompts (families)
    return SessionWorkload(vocab=cfg.vocab_size, seed=seed, sessions=6,
                           turns=3, families=2, system_prefix_len=192,
                           user_isl=48, osl=4, think_time=0.02)


def serve(scheduler, router, base):
    pool = [Engine(base, cfg, params, slots=8, capacity=CAP,
                   chunk_size=CHUNK)]
    cl = Cluster({"mixed": pool}, scheduler=scheduler, router=router)
    cl.serve(sessions(42), max_wall_s=600)      # warm-up: same shapes,
    h0 = sum(e.prefix_cache.hit_tokens for e in pool)   # different seed
    rec = Recorder(sessions(0))
    m = cl.serve(rec, max_wall_s=600)           # measured, steady-state
    hits = sum(e.prefix_cache.hit_tokens for e in pool) - h0
    mean_ftl = float(np.mean([r.ftl for r in rec.emitted]))
    return m, hits, mean_ftl, cl


m_aff, hits_aff, ftl_aff, cl_aff = serve(PrefixAffinityScheduler(CHUNK),
                                         KVLocalityRouter(), 0)
m_fcfs, hits_fcfs, ftl_fcfs, cl_fcfs = serve(FCFSScheduler(),
                                             RoundRobinRouter(), 10)

print("== 6 sessions x 3 turns, shared system prompts, think-time 20 ms ==")
for name, m, hits, ftl, cl in [
        ("affinity", m_aff, hits_aff, ftl_aff, cl_aff),
        ("naive   ", m_fcfs, hits_fcfs, ftl_fcfs, cl_fcfs)]:
    print(f"{name}: completed={m['completed']:.0f} "
          f"mean_ftl={ftl*1e3:.1f}ms "
          f"cache_hit_tokens={hits} transfers={cl.stats.transfers}")
assert m_aff["completed"] == m_fcfs["completed"] == 18
assert hits_aff > hits_fcfs, "affinity must reuse cached prefixes"
assert ftl_aff < ftl_fcfs, "reuse must shorten time-to-first-token"
print(f"-> affinity reused {hits_aff} prompt tokens "
      f"(naive full-prefills everything: {hits_fcfs}) and cut mean FTL "
      f"{ftl_fcfs/ftl_aff:.2f}x")

# the same scenario object drives the analytic sweep: its reuse fraction
# shifts the Pareto frontier (prefill compute shrinks, KV residency doesn't)
summary = sessions(0).summary()
f_reuse = workload_frontier(LLAMA31_70B, summary, max_chips=16)
f_cold = workload_frontier(
    LLAMA31_70B, type(summary)(isl=summary.isl, osl=summary.osl,
                               rate=summary.rate, reuse_fraction=0.0),
    max_chips=16)
best_reuse = max(t for _, t in f_reuse)
best_cold = max(t for _, t in f_cold)
print(f"analytic marginals: isl={summary.isl:.0f} osl={summary.osl:.0f} "
      f"reuse={summary.reuse_fraction:.2f}")
print(f"frontier peak tok/s/chip: {best_reuse:.1f} with reuse "
      f"vs {best_cold:.1f} cold -> {best_reuse/best_cold:.2f}x")
assert best_reuse >= best_cold
print("multi_turn_sessions OK — closed-loop workload served and swept")
