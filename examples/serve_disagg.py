"""End-to-end disaggregated serving driver (the paper's system, executable).

Builds both of the paper's Fig 2 deployments as *policy configurations* of
the same ``Cluster`` runtime: disaggregated = separate prefill/decode role
pools with KV handoff; co-located = one dual-role pool where prefills
preempt decode. Same traffic through both demonstrates the §2 tension on
real compute: co-located p99 TTL inflates because decode stalls behind
prefills; the disaggregated decode pool's TTL tail stays flat. Also
demonstrates elastic failover by killing a decode engine mid-run.

  PYTHONPATH=src python examples/serve_disagg.py
"""
import jax

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.cluster import Cluster
from repro.serving.engine import Engine
from repro.serving.policies import (ElasticPolicy, FCFSScheduler,
                                    KVLocalityRouter, LeastLoadedRouter)
from repro.workloads import Burst, FixedShape, OpenLoopWorkload

cfg = get_smoke_config("phi3-medium-14b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
ISL, OSL, N = 96, 8, 10
CAP = ISL + OSL + 8


def traffic(seed):
    # a real burst arrival process (not the old rate=1e6 Poisson hack)
    return OpenLoopWorkload(Burst(N, at=0.0), FixedShape(ISL, OSL),
                            vocab=cfg.vocab_size, seed=seed)


def engines(n, base):
    return [Engine(base + i, cfg, params, slots=4, capacity=CAP)
            for i in range(n)]


print(f"== prefill-heavy traffic: ISL={ISL} OSL={OSL}, {N} requests ==")

# --- disaggregated: 1 prefill + 2 decode engines -------------------------
dis = Cluster({"prefill": engines(1, 0), "decode": engines(2, 10)},
              scheduler=FCFSScheduler(), router=LeastLoadedRouter(),
              rate_matcher=ElasticPolicy())
m_dis = dis.serve(traffic(1))
print("disaggregated:", {k: round(v, 4) for k, v in m_dis.items()})
print(f"  kv transfers: {dis.stats.transfers} "
      f"({dis.stats.transferred_bytes/2**20:.1f} MiB)")

# --- co-located: 3 dual-role engines, prefill preempts decode ------------
co = Cluster({"mixed": engines(3, 20)},
             scheduler=FCFSScheduler(), router=KVLocalityRouter())
m_co = co.serve(traffic(2))
print("co-located   :", {k: round(v, 4) for k, v in m_co.items()})
assert co.stats.transfers == 0      # KV never leaves the producing engine

tail_dis = m_dis["p99_ttl_s"] / max(m_dis["p50_ttl_s"], 1e-9)
tail_co = m_co["p99_ttl_s"] / max(m_co["p50_ttl_s"], 1e-9)
print(f"TTL tail (p99/p50): disagg {tail_dis:.1f}x vs coloc {tail_co:.1f}x "
      f"-> decode interference {'ELIMINATED' if tail_dis < tail_co else '??'}")

# --- fault tolerance: kill a decode engine mid-flight ---------------------
print("== failure drill: decode engine dies mid-run ==")
pre, d1, d2 = engines(1, 30)[0], *engines(2, 40)
orch = Cluster({"prefill": [pre], "decode": [d1, d2]},
               rate_matcher=ElasticPolicy())
orig = d1.decode_step
state = {"fired": False}
def flaky(toks):
    if len(d1.step_times) >= 2 and not state["fired"]:
        state["fired"] = True
        d1.fail()
    return orig(toks)
d1.decode_step = flaky
m_fail = orch.serve(traffic(3))
print(f"completed {m_fail['completed']}/{N} despite "
      f"{orch.stats.engine_failures} engine failure(s); "
      f"{orch.stats.requeued} request(s) re-queued and replayed")
assert m_fail["completed"] == N
print("serve_disagg OK")
