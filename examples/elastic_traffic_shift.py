"""Dynamic rate matching under a traffic shift (paper §4.3, Figs 9-10),
executable: traffic flips from prefill-heavy to generation-heavy mid-run and
the ``ElasticPolicy`` rate matcher migrates engines between role pools to
re-balance — the runtime analogue of the analytic finding that the optimal
ctx:gen ratio moves with traffic. A second run pins the split with
``StaticSplitRateMatcher`` (the analytic Appendix-B alpha held fixed, the
paper's Fig 10 baseline) to show what *not* adapting costs.

  PYTHONPATH=src python examples/elastic_traffic_shift.py
"""
import jax

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.cluster import Cluster
from repro.serving.elastic import ElasticConfig, ElasticRateMatcher
from repro.serving.engine import Engine
from repro.serving.policies import ElasticPolicy, StaticSplitRateMatcher
from repro.workloads import Burst, FixedShape, OpenLoopWorkload, Superpose

cfg = get_smoke_config("qwen3-14b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
CAP = 128 + 16


def engines(ids):
    return [Engine(i, cfg, params, slots=4, capacity=CAP) for i in ids]


def traffic():
    """The traffic flip as one workload object: a prefill-heavy burst at
    t=0 superposed with a generation-heavy burst right behind it (the old
    version faked this with two rate=1e6 TrafficGens and hand-edited
    arrival_t offsets)."""
    phase1 = OpenLoopWorkload(Burst(8, at=0.0), FixedShape(96, 4),
                              vocab=cfg.vocab_size, seed=1)
    phase2 = OpenLoopWorkload(Burst(8, at=1e-3), FixedShape(16, 24),
                              vocab=cfg.vocab_size, seed=2, start_rid=100)
    return Superpose([phase1, phase2])


# --- dynamic: elastic rate matcher moves engines with the traffic ---------
elastic = ElasticPolicy(ElasticRateMatcher(ElasticConfig(
    check_every=2, queue_high=2, occupancy_high=0.8)))
orch = Cluster({"prefill": engines([0]), "decode": engines([10, 11, 12])},
               rate_matcher=elastic)
ratio_before = len(orch.prefill_pool) / len(orch.decode_pool)
metrics = orch.serve(traffic())
ratio_after = len(orch.prefill_pool) / max(len(orch.decode_pool), 1)

print("dynamic :", {k: round(v, 4) for k, v in metrics.items()})
print(f"ctx:gen engine ratio {ratio_before:.2f} -> {ratio_after:.2f}")
print(f"elastic moves: {elastic.moves}")
print(f"requeued during rebalance: {orch.stats.requeued}")
assert metrics["completed"] == 16
assert elastic.moves, "expected the rate matcher to migrate engines"

# --- static: the same fleet pinned at the analytic 1:3 split --------------
static = Cluster({"prefill": engines([20]), "decode": engines([30, 31, 32])},
                 rate_matcher=StaticSplitRateMatcher(1 / 3))
m_static = static.serve(traffic())
print("static  :", {k: round(v, 4) for k, v in m_static.items()})
assert m_static["completed"] == 16
assert not static.rate_matcher.moves[1:], "static split must not keep moving"
print("elastic_traffic_shift OK — the ctx:gen ratio adapted at runtime")
