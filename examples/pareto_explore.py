"""Design-space exploration: build the throughput-interactivity Pareto
frontier for any model (paper models or assigned archs) and print the
rate-matched deployment behind each frontier point.

  PYTHONPATH=src python examples/pareto_explore.py --model deepseek-r1 \
      --isl 16384 --osl 512
  PYTHONPATH=src python examples/pareto_explore.py --model kimi-k2-1t-a32b
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.design_space import sweep_decode, sweep_prefill
from repro.core.frontiers import colocated_frontier, default_ttl_targets
from repro.core.pareto import area_under_frontier, pareto_frontier
from repro.core.paper_models import (DEEPSEEK_R1, LLAMA31_8B, LLAMA31_70B,
                                     LLAMA31_405B, perf_llm_from_config)
from repro.core.rate_matching import dynamic_rate_match

PAPER = {m.name: m for m in (DEEPSEEK_R1, LLAMA31_8B, LLAMA31_70B,
                             LLAMA31_405B)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="deepseek-r1",
                    help=f"one of {sorted(PAPER)} or --arch ids {ARCH_IDS}")
    ap.add_argument("--isl", type=int, default=16384)
    ap.add_argument("--osl", type=int, default=512)
    ap.add_argument("--max-chips", type=int, default=256)
    ap.add_argument("--ftl-cutoff", type=float, default=10.0)
    args = ap.parse_args(argv)

    model = (PAPER[args.model] if args.model in PAPER
             else perf_llm_from_config(get_config(args.model)))
    print(f"# {model.name}: {model.params()/1e9:.1f}B params "
          f"({model.active_params()/1e9:.1f}B active), "
          f"kv/token={model.kv_bytes_per_token()/1024:.1f}KiB, "
          f"traffic ISL={args.isl} OSL={args.osl}")

    pre = sweep_prefill(model, args.isl, max_chips=args.max_chips)
    dec = sweep_decode(model, args.isl + args.osl // 2,
                       max_chips=args.max_chips,
                       max_ctx=args.isl + args.osl)
    print(f"# design points: {len(pre)} prefill x {len(dec)} decode")

    matched = dynamic_rate_match(pre, dec, isl=args.isl, osl=args.osl,
                                 ftl_cutoff=args.ftl_cutoff,
                                 ttl_targets=default_ttl_targets(20))
    print("tps_per_user,tok_s_chip,ctx:gen,prefill_map,decode_map,"
          "decode_batch")
    frontier = pareto_frontier([(r.tps_per_user, r.overall_tput_per_chip)
                                for r in matched])
    seen = set()
    for r in sorted(matched, key=lambda r: r.tps_per_user):
        key = (round(r.tps_per_user, 1), round(r.overall_tput_per_chip, 1))
        if (r.tps_per_user, r.overall_tput_per_chip) not in frontier or \
                key in seen:
            continue
        seen.add(key)
        pm, dm = r.prefill.mapping, r.decode.mapping
        print(f"{r.tps_per_user:.1f},{r.overall_tput_per_chip:.2f},"
              f"{r.ctx_gen_ratio:.2f},"
              f"g{pm.chips}/tp{pm.tp}/pp{pm.pp}/cpp{pm.cpp_chunks},"
              f"g{dm.chips}/tp{dm.tp}/dp{dm.dp_attn},{r.decode.batch}")

    f_co = colocated_frontier(model, args.isl, args.osl,
                              max_chips=args.max_chips)
    a_dis = area_under_frontier(frontier, 10, 300)
    a_co = area_under_frontier(f_co, 10, 300)
    print(f"# area[10..300 tok/s/user]: disagg={a_dis:.1f} coloc={a_co:.1f} "
          f"gain={a_dis/max(a_co, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
