"""Quickstart: train a tiny model, checkpoint it, then serve it
disaggregated — the whole substrate in ~40 lines of user code.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core.traffic import TrafficPattern
from repro.data.pipeline import make_pipeline
from repro.serving.disagg import DisaggOrchestrator
from repro.serving.engine import Engine
from repro.serving.request import TrafficGen
from repro.train.trainer import Trainer

# 1. pick an assigned architecture (smoke-sized for CPU)
cfg = get_smoke_config("granite-moe-1b-a400m")
print(f"model: {cfg.name}  params={cfg.param_count():,}")

# 2. train it for a few steps (fault-tolerant loop, checkpoints included)
data = make_pipeline(cfg, seq_len=48, global_batch=4)
trainer = Trainer(cfg, data, ckpt_dir="/tmp/quickstart_ckpt", ckpt_every=10,
                  lr=5e-3)
trainer.train(15)
print(f"trained to step {trainer.step}; "
      f"loss {trainer.history[0]['loss']:.3f} -> "
      f"{trainer.history[-1]['loss']:.3f}")

# 3. serve it disaggregated: 1 prefill engine + 1 decode engine, KV handoff
prefill_pool = [Engine(0, cfg, trainer.params, slots=4, capacity=64)]
decode_pool = [Engine(1, cfg, trainer.params, slots=4, capacity=64)]
orch = DisaggOrchestrator(prefill_pool, decode_pool)

gen = TrafficGen(vocab=cfg.vocab_size, rate=30.0,
                 pattern=TrafficPattern("quick", isl=32, osl=8), seed=0)
metrics = orch.run(gen.generate(10.0, max_requests=6))
print("serving metrics:", {k: round(v, 4) for k, v in metrics.items()})
print(f"KV transfers: {orch.stats.transfers} "
      f"({orch.stats.transferred_bytes / 2**20:.1f} MiB)")
assert metrics["completed"] == 6
print("quickstart OK")
