"""Quickstart: train a tiny model, checkpoint it, then serve it
disaggregated — the whole substrate in ~40 lines of user code.

  PYTHONPATH=src python examples/quickstart.py
"""
import shutil
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import make_pipeline
from repro.serving.cluster import Cluster
from repro.serving.engine import Engine
from repro.train.trainer import Trainer
from repro.workloads import FixedShape, OpenLoopWorkload, Poisson

# 1. pick an assigned architecture (smoke-sized for CPU)
cfg = get_smoke_config("granite-moe-1b-a400m")
print(f"model: {cfg.name}  params={cfg.param_count():,}")

# 2. train it for a few steps (fault-tolerant loop, checkpoints included);
# fresh ckpt dir per run (a reused one would restore past the train loop)
ckpt_dir = tempfile.mkdtemp(prefix="quickstart_")
try:
    data = make_pipeline(cfg, seq_len=48, global_batch=4)
    trainer = Trainer(cfg, data, ckpt_dir=ckpt_dir, ckpt_every=10, lr=5e-3)
    trainer.train(15)
    print(f"trained to step {trainer.step}; "
          f"loss {trainer.history[0]['loss']:.3f} -> "
          f"{trainer.history[-1]['loss']:.3f}")

    # 3. serve it disaggregated: 1 prefill + 1 decode engine, KV handoff
    cluster = Cluster({
        "prefill": [Engine(0, cfg, trainer.params, slots=4, capacity=64)],
        "decode": [Engine(1, cfg, trainer.params, slots=4, capacity=64)]})

    work = OpenLoopWorkload(Poisson(30.0), FixedShape(isl=32, osl=8),
                            vocab=cfg.vocab_size, seed=0,
                            max_requests=6, horizon_s=10.0)
    metrics = cluster.serve(work)
    print("serving metrics:", {k: round(v, 4) for k, v in metrics.items()})
    print(f"KV transfers: {cluster.stats.transfers} "
          f"({cluster.stats.transferred_bytes / 2**20:.1f} MiB)")
    assert metrics["completed"] == 6
finally:
    shutil.rmtree(ckpt_dir, ignore_errors=True)
print("quickstart OK")
