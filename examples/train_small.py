"""Train a ~20M-param dense LM for a few hundred steps on CPU with the full
fault-tolerant loop (checkpoints, resume, straggler monitor), showing the
loss decreasing on the structured synthetic stream.

  PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse

from repro.data.pipeline import make_pipeline
from repro.models.config import ModelConfig
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/train_small_ckpt")
    args = ap.parse_args(argv)

    cfg = ModelConfig(
        name="dense-20m", family="dense",
        num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
        d_ff=1024, vocab_size=8192, remat=False, logits_chunk=128)
    print(f"{cfg.name}: {cfg.param_count():,} params")

    data = make_pipeline(cfg, seq_len=128, global_batch=8, seed=0)
    tr = Trainer(cfg, data, ckpt_dir=args.ckpt_dir, ckpt_every=50, lr=1e-3)
    start = tr.init_or_restore()
    print(f"starting at step {start}")
    tr.train(args.steps, on_step=lambda s, m: (
        print(f"step {s:4d}  loss {m['loss']:.4f}  "
              f"({m['step_s']*1e3:.0f} ms)") if s % 20 == 0 else None))
    losses = [h["loss"] for h in tr.history]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(tr.monitor.events)} straggler events)")
    assert losses[-1] < losses[0]
    print("train_small OK")


if __name__ == "__main__":
    main()
