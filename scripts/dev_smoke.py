"""Dev smoke: tiny configs of each family through train/prefill/decode."""
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models import transformer as T

TINY = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
            d_ff=128, vocab_size=97, remat=False, logits_chunk=16)

cfgs = [
    ModelConfig(name="tiny-dense", family="dense", **TINY),
    ModelConfig(name="tiny-bias", family="dense", qkv_bias=True, qk_norm=True,
                **TINY),
    ModelConfig(name="tiny-moe", family="moe",
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              num_shared_experts=1), **TINY),
    ModelConfig(name="tiny-rwkv", family="ssm", block="rwkv", **TINY),
    ModelConfig(name="tiny-hybrid", family="hybrid", block="hybrid",
                sliding_window=8, ssm_state=4, **TINY),
    ModelConfig(name="tiny-vlm", family="dense", frontend="vision",
                vision_patches=6, vision_dim=32, **TINY),
]

key = jax.random.PRNGKey(0)
B, S = 2, 24
for cfg in cfgs:
    params = T.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision":
        batch["tokens"] = tokens[:, :S - cfg.vision_patches]
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vision_patches, cfg.vision_dim), jnp.bfloat16)
    loss, metrics = jax.jit(lambda p, b: T.train_loss(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), (cfg.name, loss)
    # grads
    g = jax.grad(lambda p: T.train_loss(p, cfg, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in jax.tree.leaves(g)))
    assert jnp.isfinite(gn), cfg.name
    # prefill + decode
    pre_inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(
        lambda p, i: T.prefill_full(p, cfg, i, capacity=S + 8))(params, pre_inputs)
    assert logits.shape == (B, cfg.vocab_size), (cfg.name, logits.shape)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), cfg.name
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: T.decode_step(p, cfg, c, t))(params, cache, nxt)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all(), cfg.name
    assert (cache2["pos"] == cache["pos"] + 1).all()
    print(f"OK {cfg.name:12s} params={n_params:,} loss={float(loss):.3f} "
          f"gnorm={float(gn):.3f}")
print("all families OK")
