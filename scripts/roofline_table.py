"""Aggregate results/dryrun JSONs into the §Roofline markdown table."""
import glob
import json
import sys


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main(mesh="single"):
    rows = []
    for f in sorted(glob.glob(f"results/dryrun/*__{mesh}.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], None, r.get("error", "?")))
            continue
        rows.append((r["arch"], r["shape"], r, None))

    print("| arch | shape | peak GiB/dev | compute | memory | collective |"
          " dominant | MODEL/HLO | roofline frac | one-line action |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch, shape, r, err in rows:
        if r is None:
            print(f"| {arch} | {shape} | FAIL | {err} |")
            continue
        rf = r["roofline"]
        peak = r["memory"]["peak_per_device"] / 2**30
        print(f"| {arch} | {shape} | {peak:.2f} | {fmt_s(rf['compute_s'])} |"
              f" {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} |"
              f" {rf['dominant']} | {rf['flops_ratio']:.2f} |"
              f" {rf['roofline_fraction']:.3f} | |")


if __name__ == "__main__":
    main(*(sys.argv[1:] or ["single"]))
