#!/usr/bin/env bash
# CI entry point: the tier-1 verify command (ROADMAP.md) plus the per-family
# model smoke. Run from anywhere; conftest.py also injects src/ so a bare
# `python -m pytest -x -q` from the repo root collects cleanly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: all model families =="
python scripts/dev_smoke.py

echo "== smoke: examples (tiny configs) =="
python examples/quickstart.py
python examples/multi_turn_sessions.py

echo "== trace corpus goldens =="
python -m pytest -q tests/test_trace_corpus.py

echo "== hetero benchmark (smoke) =="
rm -f BENCH_hetero.json
python benchmarks/serving_policies.py --workload burst --smoke \
    --prefill-chip v5p --decode-chip v5e --out -
python - <<'PY'
import json, sys
try:
    with open("BENCH_hetero.json") as f:
        d = json.load(f)
except FileNotFoundError:
    sys.exit("BENCH_hetero.json missing: hetero benchmark did not emit it")
a = d["analytic"]
assert a["hetero"]["frontier"] and a["homog_decode_chip"]["frontier"], \
    "empty frontier in BENCH_hetero.json"
assert a["hetero_ge_homog"], \
    "heterogeneous frontier fell below homogeneous at equal chip budget"
assert len(d["runtime"]) == 2 and all(
    r["completed"] > 0 for r in d["runtime"]), d["runtime"]
print("BENCH_hetero.json OK: hetero area %.1f >= homog area %.1f"
      % (a["hetero"]["area"], a["homog_decode_chip"]["area"]))
PY

echo "CI OK"
