#!/usr/bin/env bash
# CI entry point: the tier-1 verify command (ROADMAP.md) plus the per-family
# model smoke. Run from anywhere; conftest.py also injects src/ so a bare
# `python -m pytest -x -q` from the repo root collects cleanly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== lint: repro.analysis (layering/determinism/units/contracts/hotpath) =="
python -m repro.analysis --json > /tmp/analysis.json \
    || { cat /tmp/analysis.json; exit 1; }
python - <<'PY'
import json
d = json.load(open("/tmp/analysis.json"))
assert d["ok"] and not d["violations"], d["violations"]
for name, t in sorted(d.get("timings", {}).items()):
    print("  pass %-12s %7.1f ms" % (name, t * 1e3))
print("repro.analysis OK: %d modules checked, %d baselined finding(s)"
      % (d["checked_modules"], len(d["baselined"])))
PY

echo "== lint: sanitizer-enabled serving loop + policy-purity guard =="
REPRO_SANITIZE=1 python -m pytest -q \
    tests/test_simengine.py::test_sim_failure_requeues_and_replays_identically \
    "tests/test_analysis.py::test_purity_guard_trips_on_mutating_policy"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: all model families =="
python scripts/dev_smoke.py

echo "== smoke: examples (tiny configs) =="
python examples/quickstart.py
python examples/multi_turn_sessions.py

echo "== trace corpus goldens =="
python -m pytest -q tests/test_trace_corpus.py

echo "== hetero benchmark (smoke) =="
rm -f BENCH_hetero.json
python benchmarks/serving_policies.py --workload burst --smoke \
    --prefill-chip v5p --decode-chip v5e --out -
python - <<'PY'
import json, sys
try:
    with open("BENCH_hetero.json") as f:
        d = json.load(f)
except FileNotFoundError:
    sys.exit("BENCH_hetero.json missing: hetero benchmark did not emit it")
a = d["analytic"]
assert a["hetero"]["frontier"] and a["homog_decode_chip"]["frontier"], \
    "empty frontier in BENCH_hetero.json"
assert a["hetero_ge_homog"], \
    "heterogeneous frontier fell below homogeneous at equal chip budget"
assert len(d["runtime"]) == 2 and all(
    r["completed"] > 0 for r in d["runtime"]), d["runtime"]
print("BENCH_hetero.json OK: hetero area %.1f >= homog area %.1f"
      % (a["hetero"]["area"], a["homog_decode_chip"]["area"]))
PY

echo "== sweep engine (smoke) =="
SWEEP_STORE="$(mktemp -d)"
trap 'rm -rf "$SWEEP_STORE"' EXIT
python -m repro.launch.sweep --models llama-3.1-8b \
    --hardware v5e v5p:v5e --isl 512 --osl 64 --reuse 0.0 0.5 \
    --modes disagg coloc --ttl-targets 6 --max-chips 16 \
    --store "$SWEEP_STORE" --quiet > /tmp/sweep_run1.json
python -m repro.launch.sweep --models llama-3.1-8b \
    --hardware v5e v5p:v5e --isl 512 --osl 64 --reuse 0.0 0.5 \
    --modes disagg coloc --ttl-targets 6 --max-chips 16 \
    --store "$SWEEP_STORE" --quiet > /tmp/sweep_run2.json
rm -f BENCH_sweep.json
python benchmarks/sweep_scale.py --smoke --fresh \
    --store "$SWEEP_STORE/bench" > /dev/null
python - <<'PY'
import json, sys
r1 = json.load(open("/tmp/sweep_run1.json"))
r2 = json.load(open("/tmp/sweep_run2.json"))
assert r1["cells_run"] == r1["cells_total"] > 0, r1
assert r2["cells_run"] == 0 and r2["cells_cached"] == r1["cells_total"], \
    f"second sweep run was not a full cache hit: {r2}"
assert r2["points"] == r1["points"] and r2["records"] == r1["records"]
assert r2["frontier_areas"] == r1["frontier_areas"]
try:
    d = json.load(open("BENCH_sweep.json"))
except FileNotFoundError:
    sys.exit("BENCH_sweep.json missing: sweep benchmark did not emit it")
required = {"bench", "spec_hash", "cells", "points", "elapsed_s",
            "points_per_s", "eval_points_per_s", "baseline_points_per_s",
            "speedup", "cache_hit_rerun_s", "frontier_areas"}
missing = required - set(d)
assert not missing, f"BENCH_sweep.json missing keys: {sorted(missing)}"
assert d["points"] > 0 and d["speedup"] > 1.0, d
assert d["cache_hit_rerun_s"] < d["elapsed_s"] or d["cells_cached"] > 0, d
print("sweep smoke OK: %d cells cached on rerun, smoke speedup %.1fx"
      % (r2["cells_cached"], d["speedup"]))
PY

echo "== sim backend (smoke) =="
rm -f BENCH_sim.json
python benchmarks/sim_speed.py --smoke > /dev/null
python - <<'PY'
import json, sys
try:
    with open("BENCH_sim.json") as f:
        d = json.load(f)
except FileNotFoundError:
    sys.exit("BENCH_sim.json missing: sim benchmark did not emit it")
required = {"bench", "smoke", "model", "workload", "real", "sim",
            "speedup", "floor", "parity"}
missing = required - set(d)
assert not missing, f"BENCH_sim.json missing keys: {sorted(missing)}"
assert d["floor"] >= 50.0 and d["speedup"] >= d["floor"], d
assert all(d["parity"].values()), f"schedules diverged: {d['parity']}"
for side in ("real", "sim"):
    assert d[side]["completed"] > 0 and d[side]["rps"] > 0, d[side]
print("BENCH_sim.json OK: sim backend %.0fx over real (floor %.0fx)"
      % (d["speedup"], d["floor"]))
PY

echo "== real engine: paged vs dense KV layout (smoke) =="
rm -f BENCH_engine.json
python benchmarks/engine_speed.py --smoke > /dev/null
python - <<'PY'
import json, sys
try:
    with open("BENCH_engine.json") as f:
        d = json.load(f)
except FileNotFoundError:
    sys.exit("BENCH_engine.json missing: engine benchmark did not emit it")
required = {"bench", "smoke", "model", "workload", "dense", "paged",
            "speedup", "floor", "streams_identical", "stream_sha256",
            "payload_ratio"}
missing = required - set(d)
assert not missing, f"BENCH_engine.json missing keys: {sorted(missing)}"
assert d["floor"] >= 2.0 and d["speedup"] >= d["floor"], d
assert d["streams_identical"], \
    "paged and dense token streams diverged (bit-identity broken)"
for side in ("dense", "paged"):
    assert d[side]["decode_tokens_per_s"] > 0, d[side]
assert d["payload_ratio"] >= 1.0, d
print("BENCH_engine.json OK: paged decode %.1fx over dense (floor %.1fx), "
      "KV payload %.1fx smaller, streams byte-identical"
      % (d["speedup"], d["floor"], d["payload_ratio"]))
PY

echo "== fleet-scale event loop (smoke) =="
rm -f BENCH_fleet.json
python benchmarks/fleet_scale.py --smoke > /dev/null
python - <<'PY'
import json, sys
try:
    with open("BENCH_fleet.json") as f:
        d = json.load(f)
except FileNotFoundError:
    sys.exit("BENCH_fleet.json missing: fleet benchmark did not emit it")
required = {"bench", "smoke", "model", "fleet", "workload", "wall_s", "rps",
            "completed", "arrived", "peak_rss_mb", "floor_rps",
            "rss_ceiling_mb", "primed_grid_points", "traced", "virtual"}
missing = required - set(d)
assert not missing, f"BENCH_fleet.json missing keys: {sorted(missing)}"
assert d["completed"] >= d["workload"]["requests"] > 0, d
assert d["rps"] >= d["floor_rps"] > 0, \
    f"fleet rate {d['rps']} below floor {d['floor_rps']}"
assert 0 < d["peak_rss_mb"] <= d["rss_ceiling_mb"], d
assert d["primed_grid_points"] > 0, "decode grid was not primed"
t = d["traced"]
assert t["schedule_identical"], \
    "traced fleet episode diverged from untraced (recorder perturbed it)"
assert t["overhead"] <= t["overhead_limit"], \
    f"tracing overhead {t['overhead']:.1%} above {t['overhead_limit']:.0%}"
assert t["events"] > 0, "traced episode recorded no events"
print("BENCH_fleet.json OK: %s engines -> %.0f req/s (floor %.0f), "
      "peak RSS %.0f MB (ceiling %.0f), tracing overhead %+.1f%% "
      "(limit %.0f%%)"
      % (d["fleet"]["engines"], d["rps"], d["floor_rps"],
         d["peak_rss_mb"], d["rss_ceiling_mb"], 100 * t["overhead"],
         100 * t["overhead_limit"]))
PY

echo "== observability: span trace export + attribution (smoke) =="
python -m repro.launch.serve --backend sim --workload burst --requests 16 \
    --trace-out /tmp/trace_smoke.json > /tmp/serve_obs.json
python - <<'PY'
import json, sys
sys.path.insert(0, "src")
from repro.serving.obs import validate_trace
trace = json.load(open("/tmp/trace_smoke.json"))
counts = validate_trace(trace)
assert counts["b"] == counts["e"] > 0, counts
assert counts["X"] > 0 and counts["M"] > 0, counts
phases = {e["name"] for e in trace["traceEvents"] if e["ph"] == "b"}
assert phases <= {"queue", "prefill", "transfer", "decode"}, phases
m = trace["otherData"]["metrics"]
for k in ("p50_queue_wait_s", "p99_queue_wait_s", "p50_prefill_s",
          "p99_prefill_s", "p50_transfer_s", "p99_transfer_s",
          "p50_decode_stall_s", "p99_decode_stall_s"):
    assert k in m, f"attribution column {k} missing from trace metrics"
print("trace schema OK: %d events (%d slices, %d async, %d counters), "
      "attribution columns present"
      % (counts["total"], counts["X"], counts["b"] + counts["e"],
         counts["C"]))
PY

echo "== simulator-in-the-loop sweep (smoke) =="
SIM_SWEEP_ARGS=(--models llama-3.1-8b --hardware v5e --isl 256 --osl 32
    --reuse 0.0 0.5 --modes disagg coloc --ttl-targets 4 --max-chips 8
    --simulate --sim-requests 8 --store "$SWEEP_STORE/sim" --quiet)
python -m repro.launch.sweep "${SIM_SWEEP_ARGS[@]}" > /tmp/simsweep_run1.json
python -m repro.launch.sweep "${SIM_SWEEP_ARGS[@]}" > /tmp/simsweep_run2.json
python - <<'PY'
import json
r1 = json.load(open("/tmp/simsweep_run1.json"))
r2 = json.load(open("/tmp/simsweep_run2.json"))
assert r1["cells_run"] == r1["cells_total"] > 0, r1
assert r2["cells_run"] == 0 and r2["cells_cached"] == r1["cells_total"], \
    f"second simulate sweep was not a full cache hit: {r2}"
assert r2["frontier_areas"] == r1["frontier_areas"]
sim_areas = [k for k in r1["frontier_areas"] if k.endswith("/sim")]
assert sim_areas, f"no simulated frontier areas: {r1['frontier_areas']}"
print("simulate sweep OK: %d cells cached on rerun, sim areas %s"
      % (r2["cells_cached"], sim_areas))
PY

echo "CI OK"
