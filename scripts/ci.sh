#!/usr/bin/env bash
# CI entry point: the tier-1 verify command (ROADMAP.md) plus the per-family
# model smoke. Run from anywhere; conftest.py also injects src/ so a bare
# `python -m pytest -x -q` from the repo root collects cleanly.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: all model families =="
python scripts/dev_smoke.py

echo "== smoke: examples (tiny configs) =="
python examples/quickstart.py
python examples/multi_turn_sessions.py

echo "CI OK"
