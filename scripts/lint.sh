#!/usr/bin/env bash
# Architecture & determinism lint: wraps `python -m repro.analysis`
# (import-graph layering, determinism hazards, dimensional consistency,
# plugin contracts, hot-path complexity, SweepSpec hash stability).
#
#   scripts/lint.sh                    # human-readable report, exit 1 on
#                                      # any finding not in the baseline
#   scripts/lint.sh --changed          # only files changed vs HEAD
#                                      # (plus untracked), the fast loop
#   scripts/lint.sh --json             # machine-readable (CI)
#   scripts/lint.sh --write-baseline   # accept current findings
#   scripts/lint.sh --explain RULE     # a rule's rationale and fix
#
# Policy and baseline live next to the package:
# src/repro/analysis/{policy.json,baseline.json}.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

args=()
for a in "$@"; do
    if [ "$a" = "--changed" ]; then
        changed=$( (git diff --name-only HEAD -- '*.py';
                    git ls-files --others --exclude-standard -- '*.py') \
                   | sort -u)
        if [ -z "$changed" ]; then
            echo "lint.sh --changed: no changed .py files"
            exit 0
        fi
        # shellcheck disable=SC2206
        args+=(--files $changed)
    else
        args+=("$a")
    fi
done
exec python -m repro.analysis "${args[@]+"${args[@]}"}"
