#!/usr/bin/env bash
# Architecture & determinism lint: wraps `python -m repro.analysis`
# (import-graph layering, determinism hazards, SweepSpec hash stability).
#
#   scripts/lint.sh                    # human-readable report, exit 1 on
#                                      # any finding not in the baseline
#   scripts/lint.sh --json             # machine-readable (CI)
#   scripts/lint.sh --write-baseline   # accept current findings
#
# Policy and baseline live next to the package:
# src/repro/analysis/{policy.json,baseline.json}.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
