"""Regenerate the sweep-engine golden: tests/data/sweeps/golden_small.json.

A tiny-but-representative grid (dense + MLA/MoE model, homogeneous +
heterogeneous hardware, both serving modes, a reuse axis) swept into a
throwaway store; the resulting records are the golden. Rerun after any
*intentional* perf-model or rate-matching change:

    PYTHONPATH=src python scripts/gen_sweep_golden.py

The engine is deterministic (pure float64 arithmetic, no RNG, no
wall-clock in records), so regeneration on any platform must be a no-op
unless the model changed.
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sweeps import SweepResult, SweepSpec, SweepStore, run_sweep

OUT = os.path.join(os.path.dirname(__file__), "..",
                   "tests", "data", "sweeps", "golden_small.json")


def golden_spec() -> SweepSpec:
    return SweepSpec.create(
        models=["llama-3.1-8b", "deepseek-r1"],
        hardware=["v5e", "v5p", "v5p:v5e"],
        isl=[512], osl=[64], reuse=[0.0, 0.5],
        modes=["disagg", "coloc"], ttl_targets=8, max_chips=16)


def main() -> None:
    spec = golden_spec()
    with tempfile.TemporaryDirectory() as root:
        store = SweepStore(root)
        report = run_sweep(spec, store)
        records = SweepResult(store, spec).records()
    blob = {
        "spec": spec.canonical(),
        "spec_hash": spec.spec_hash(),
        "points": report.points,
        "records": records,
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.relpath(OUT)}: {len(records)} records, "
          f"{report.points} points, spec {spec.spec_hash()}")


if __name__ == "__main__":
    main()
