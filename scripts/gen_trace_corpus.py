"""Regenerate the checked-in trace regression corpus (tests/data/traces/).

Four small production-like JSONL traces (ROADMAP: the ``record_trace``
regression corpus), each written *with prompts* so replay token streams
are fully pinned by the file — independent of the replay seed:

  - burst.jsonl         prefill-heavy burst at t=0 (open loop)
  - diurnal.jsonl       thinned diurnal arrivals, lognormal shapes (open loop)
  - sessions.jsonl      multi-turn conversations recorded from a closed-loop
                        serve (arrival times are the recorded virtual times;
                        prompts embed the prior turns' outputs)
  - tiers.jsonl         interactive SLA tier superposed on a batch backfill
  - fleet_diurnal.jsonl two virtual days of fleet traffic compressed
                        ~4000x (the day's rate swing in ~43 s of trace
                        time), mixed request classes; golden carries the
                        per-hour arrival marginals + compression factor

Also rewrites ``golden.json``: per-trace file hashes and summary marginals
that ``tests/test_trace_corpus.py`` asserts against. Regenerating is a
deliberate act — goldens move with it:

  PYTHONPATH=src python scripts/gen_trace_corpus.py
"""
from __future__ import annotations

import hashlib
import json
import math
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.models import transformer as T  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.serving.cluster import Cluster  # noqa: E402
from repro.serving.engine import Engine  # noqa: E402
from repro.workloads import (BATCH, INTERACTIVE, Burst, Diurnal,  # noqa: E402
                             FixedShape, LognormalShape, MixtureShape,
                             OpenLoopWorkload, Recorder, SessionWorkload,
                             Superpose, TraceReplay, materialize,
                             record_trace)

OUT = pathlib.Path(__file__).resolve().parents[1] / "tests" / "data" / "traces"
VOCAB = 97

# fleet_diurnal: two virtual days squeezed so the full day/night rate swing
# fits in a replayable-in-CI trace (1 virtual day -> 21.6 s of trace time)
FLEET_COMPRESSION = 4000.0
FLEET_DAYS = 2

CFG = ModelConfig(name="trace-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=VOCAB,
                  remat=False, logits_chunk=32, dtype="float32")


def burst_requests():
    return materialize(OpenLoopWorkload(
        Burst(10, at=0.0, spacing=0.05), FixedShape(24, 6),
        vocab=VOCAB, seed=101))


def diurnal_requests():
    return materialize(OpenLoopWorkload(
        Diurnal(8.0, amplitude=0.8, period=2.0), LognormalShape(16, 5),
        vocab=VOCAB, seed=7, max_requests=10, horizon_s=60.0))


def tiers_requests():
    backfill = OpenLoopWorkload(Burst(8, at=0.0, spacing=0.02),
                                FixedShape(48, 4), vocab=VOCAB, seed=0,
                                tier=BATCH)
    urgent = OpenLoopWorkload(Burst(4, at=0.01, spacing=0.05),
                              FixedShape(12, 4), vocab=VOCAB, seed=1,
                              start_rid=100, tier=INTERACTIVE)
    return materialize(Superpose([backfill, urgent]))


def fleet_diurnal_requests():
    """Compressed multi-day fleet trace: diurnal arrivals starting at the
    overnight trough (phase -pi/2), request shapes mixing chat, long-doc,
    and short-probe classes — the workload family the fleet-scale event
    loop is benchmarked against (``benchmarks/fleet_scale.py``)."""
    period = 86400.0 / FLEET_COMPRESSION
    shape = MixtureShape([(0.7, FixedShape(12, 4)),
                          (0.2, FixedShape(32, 6)),
                          (0.1, FixedShape(8, 3))])
    return materialize(OpenLoopWorkload(
        Diurnal(1.2, amplitude=0.8, period=period, phase=-math.pi / 2),
        shape, vocab=VOCAB, seed=2026, max_requests=48,
        horizon_s=FLEET_DAYS * period))


def fleet_hourly_arrivals(reqs):
    """Per-virtual-hour arrival counts (the trace's rate marginal)."""
    hour = 86400.0 / FLEET_COMPRESSION / 24.0
    counts = [0] * (FLEET_DAYS * 24)
    for r in reqs:
        b = min(int(r.arrival_t // hour), len(counts) - 1)
        counts[b] += 1
    return counts


def session_requests():
    """Closed-loop sessions must be *served* to exist; the recorded
    arrival times are the serve's virtual times, frozen into the trace."""
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    w = Recorder(SessionWorkload(vocab=VOCAB, seed=3, sessions=3, turns=2,
                                 families=2, system_prefix_len=16,
                                 user_isl=8, osl=4, think_time=0.02))
    cl = Cluster({"mixed": [Engine(0, CFG, params, slots=4, capacity=96)]})
    m = cl.serve(w, max_wall_s=600)
    assert m["completed"] == 6, m
    return w.emitted


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    golden = {}
    for name, gen in (("burst", burst_requests),
                      ("diurnal", diurnal_requests),
                      ("sessions", session_requests),
                      ("tiers", tiers_requests),
                      ("fleet_diurnal", fleet_diurnal_requests)):
        path = OUT / f"{name}.jsonl"
        reqs = gen()
        records = record_trace(reqs, path, with_prompts=True)
        sha = hashlib.sha256(path.read_bytes()).hexdigest()
        s = TraceReplay(path, vocab=VOCAB).summary()
        golden[name] = {
            "n_requests": len(records),
            "sha256": sha,
            "summary": {"isl": round(s.isl, 6), "osl": round(s.osl, 6),
                        "rate": round(s.rate, 6)},
        }
        if name == "fleet_diurnal":
            golden[name]["compression"] = FLEET_COMPRESSION
            golden[name]["days"] = FLEET_DAYS
            golden[name]["hourly_arrivals"] = fleet_hourly_arrivals(reqs)
        print(f"{name}: {len(records)} requests -> {path}")
    with open(OUT / "golden.json", "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"goldens -> {OUT / 'golden.json'}")


if __name__ == "__main__":
    main()
