"""Decode-vs-full-forward consistency: the cornerstone of serving correctness.

prefill(tokens[:S]) then decode_step(tokens[S]) must equal
prefill(tokens[:S+1]) logits, for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, MoEConfig
from repro.models import transformer as T

TINY = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
            d_ff=128, vocab_size=97, remat=False, logits_chunk=16,
            dtype="float32")

cfgs = [
    ModelConfig(name="dense", family="dense", **TINY),
    ModelConfig(name="moe", family="moe",
                moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              num_shared_experts=1, capacity_factor=4.0),
                **TINY),
    ModelConfig(name="rwkv", family="ssm", block="rwkv", **TINY),
    ModelConfig(name="hybrid", family="hybrid", block="hybrid",
                sliding_window=8, ssm_state=4, **TINY),
]

key = jax.random.PRNGKey(1)
B, S = 2, 13
for cfg in cfgs:
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab_size)
    # reference: single-shot prefill of S+i tokens -> last logits
    lg_ref1, _ = T.prefill_full(params, cfg, {"tokens": toks[:, :S + 1]})
    # incremental: prefill S, then decode tokens S..S+2
    lg, cache = T.prefill_full(params, cfg, {"tokens": toks[:, :S]},
                               capacity=S + 8)
    lg_step1, cache = T.decode_step(params, cfg, cache, toks[:, S])
    err1 = float(jnp.max(jnp.abs(lg_step1 - lg_ref1)))
    lg_ref2, _ = T.prefill_full(params, cfg, {"tokens": toks[:, :S + 2]})
    lg_step2, cache = T.decode_step(params, cfg, cache, toks[:, S + 1])
    err2 = float(jnp.max(jnp.abs(lg_step2 - lg_ref2)))
    print(f"{cfg.name:8s} decode-vs-full err: {err1:.2e} {err2:.2e}")
    assert err1 < 2e-4 and err2 < 2e-4, cfg.name

# chunked prefill == full prefill (dense)
cfg = cfgs[0]
params = T.init_params(cfg, key)
toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)
lg_full, cache_full = T.prefill_full(params, cfg, {"tokens": toks})
lg_chunk, cache_chunk = T.prefill_chunked(params, cfg, {"tokens": toks}, 4)
err = float(jnp.max(jnp.abs(lg_full - lg_chunk)))
errk = float(jnp.max(jnp.abs(cache_full["k"] - cache_chunk["k"])))
print(f"chunked-prefill err: logits {err:.2e} cache {errk:.2e}")
assert err < 2e-4 and errk < 2e-4
print("consistency OK")
